"""Property-based scheduler-policy invariants (hypothesis; degrades to
the deterministic conftest shim when the package is missing).

Every policy must state and test its invariants before it ships — the
contract this suite pins down (see ROADMAP.md "Testing strategy"):

- conservation: across any submit/admit/complete interleaving no ticket
  is lost or duplicated — submitted == admitted + pending + shed,
- FIFO admits in arrival order,
- EDF never inverts deadlines within an admitted batch, and never leaves
  a strictly-earlier deadline waiting behind an admitted one,
- size x time batches are bucket-coherent,
- priority+aging guarantees bounded starvation (a priority-p ticket
  outranks any fresh priority-0 ticket after waiting p * aging_s),
- shed tickets never reach admit (so they can never consume an executor
  dispatch) and count only in the rejection counter,
- admission sequences are deterministic under a fixed seed,
- the router always lands a submit on a minimum-load replica, so the
  routed-count spread over an all-submit sequence is bounded by 1,
- chunked-prefill continuations (PR 3): conservation holds with
  continuation tickets in flight (submitted = finally-admitted +
  pending + shed, resubmits counted separately), a continuation never
  loses priority/aging credit or its deadline, coherent-group admission
  is bucket-pure and respects the fresh-ticket slot cap, and chunked
  admission is deterministic under a fixed seed,
- cross-replica work stealing + fault drain (PR 4, via the
  deterministic fleet sim in fleet_sim.py): fleet-wide conservation
  under arbitrary submit/steal/fail/complete interleavings (submitted =
  completed + pending-anywhere + shed, no duplication across queues), a
  stolen ticket keeps its tid/priority/deadline and aging credit — and
  is never a continuation, stealing is deterministic under a fixed
  seed, and drain_replica re-homes every pending ticket exactly once.

All tests drive the scheduler on a virtual clock (the ``now=`` hooks), so
they are exact — no wall-clock tolerance anywhere.
"""
from collections import Counter

import numpy as np
import pytest
from hypothesis import assume, given, note, settings
from hypothesis import strategies as st

from repro.core.bucketing import pick_bucket
from repro.serving.router import ReplicaRouter, spread
from repro.serving.scheduler import (NO_SLO, PriorityAgingPolicy, Scheduler,
                                     SizeTimePolicy)
from repro.serving.telemetry import Telemetry

POLICY_NAMES = ("fifo", "edf", "sizetime", "priority")


def _random_trace(rng, n):
    """(size, priority, slo_ms-or-None) per ticket plus arrival times."""
    sizes = rng.integers(1, 300, n)
    prios = rng.integers(0, 3, n)
    slos = [None if rng.random() < 0.3 else float(rng.uniform(5, 500))
            for _ in range(n)]
    arrivals = np.cumsum(rng.uniform(0.0, 0.01, n))
    return sizes, prios, slos, arrivals


# ---- conservation ---------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 40),
       policy=st.sampled_from(POLICY_NAMES),
       max_queue=st.integers(0, 1), k=st.integers(1, 6))
def test_no_ticket_lost_or_duplicated(seed, n, policy, max_queue, k):
    """Multiset identity over any interleaving: every submitted tid ends
    up exactly once in {admitted, still-pending, shed}."""
    rng = np.random.default_rng(seed)
    s = Scheduler(policy, max_queue=n // 2 if max_queue else None,
                  service_ms_est=None)
    sizes, prios, slos, arrivals = _random_trace(rng, n)
    submitted, admitted, shed = [], [], []
    now = 0.0
    for i in range(n):
        now = float(arrivals[i])
        t = s.submit(i, size=int(sizes[i]), priority=int(prios[i]),
                     slo_ms=slos[i], now=now)
        submitted.append(t)
        if t.shed:
            shed.append(t)
        if rng.random() < 0.4:                  # interleave admissions
            got = s.admit(k, now=now)
            admitted.extend(got)
            for g in got:
                s.complete(g, now=now + 0.001)
    while s.depth:                              # drain
        admitted.extend(s.admit(k, now=now))
    tids = Counter(t.tid for t in admitted) \
        + Counter(t.tid for t in shed)
    assert set(tids) == {t.tid for t in submitted}
    assert all(c == 1 for c in tids.values()), "ticket duplicated"
    assert len(admitted) + len(shed) == n


# ---- per-policy ordering invariants --------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 30),
       k=st.integers(1, 8))
def test_fifo_admits_in_arrival_order(seed, n, k):
    rng = np.random.default_rng(seed)
    s = Scheduler("fifo")
    _, _, slos, arrivals = _random_trace(rng, n)
    for i in range(n):
        s.submit(i, slo_ms=slos[i], now=float(arrivals[i]))
    prev = -1
    while s.depth:
        for t in s.admit(k, now=99.0):
            assert t.payload > prev, "FIFO inversion"
            prev = t.payload


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 30),
       k=st.integers(1, 8))
def test_edf_never_inverts_deadlines(seed, n, k):
    """Within one admitted batch deadlines are non-decreasing, and no
    ticket left pending has a strictly earlier deadline than any ticket
    in the batch (deadline-less tickets sort last)."""
    rng = np.random.default_rng(seed)
    s = Scheduler("edf")
    _, _, slos, arrivals = _random_trace(rng, n)
    for i in range(n):
        s.submit(i, slo_ms=slos[i], now=float(arrivals[i]))
    while s.depth:
        batch = s.admit(k, now=99.0)
        dls = [t.deadline_t if t.deadline_t is not None else float("inf")
               for t in batch]
        assert dls == sorted(dls), "EDF inverted deadlines within a batch"
        if s.depth:
            left = min(t.deadline_t if t.deadline_t is not None
                       else float("inf") for t in s._pending)
            assert left >= dls[-1]    # inf >= inf holds for best-effort


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 30),
       k=st.integers(1, 8))
def test_sizetime_batches_are_bucket_coherent(seed, n, k):
    buckets = (32, 64, 128, 256)
    rng = np.random.default_rng(seed)
    s = Scheduler(SizeTimePolicy(buckets))
    sizes, _, _, arrivals = _random_trace(rng, n)
    for i in range(n):
        s.submit(i, size=int(sizes[i]), now=float(arrivals[i]))
    while s.depth:
        batch = s.admit(k, now=99.0)
        got = {pick_bucket(t.size, buckets) for t in batch}
        assert len(got) == 1, f"size x time batch spans buckets {got}"


# ---- priority + aging -----------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), prio=st.integers(1, 4),
       aging_s=st.floats(0.1, 5.0))
def test_priority_aging_rank_bound(seed, prio, aging_s):
    """The documented starvation bound: once a priority-p ticket has
    waited more than p * aging_s, it outranks ANY freshly-arrived
    priority-0 ticket."""
    pol = PriorityAgingPolicy(aging_s=aging_s)
    s = Scheduler(pol)
    old = s.submit("old", priority=prio, now=0.0)
    now = prio * aging_s * 1.001            # just past the bound
    s.submit("fresh", priority=0, now=now)
    assert pol.rank(old, now) < 0.0
    assert [t.payload for t in s.admit(1, now=now)] == ["old"]


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), prio=st.integers(1, 3))
def test_priority_aging_bounded_starvation_under_stream(seed, prio):
    """A low-priority ticket competing against an endless stream of fresh
    priority-0 arrivals (one per round, one admission per round) is
    admitted within prio * aging_s / dt + backlog + 1 rounds — it cannot
    starve."""
    aging_s, dt = 0.5, 0.1
    rng = np.random.default_rng(seed)
    s = Scheduler(PriorityAgingPolicy(aging_s=aging_s))
    victim = s.submit("victim", priority=prio, now=0.0)
    bound = int(prio * aging_s / dt) + 2
    for round_i in range(bound + 1):
        now = (round_i + 1) * dt
        s.submit(f"fresh{round_i}", priority=0, now=now)
        got = s.admit(1, now=now)
        if any(t.tid == victim.tid for t in got):
            assert round_i <= bound
            return
    pytest.fail(f"priority-{prio} ticket starved past the "
                f"{bound}-round bound")


# ---- shedding -------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 40),
       policy=st.sampled_from(POLICY_NAMES))
def test_shed_tickets_never_reach_admit(seed, n, policy):
    """Shed tickets are never admitted (so they can never consume an
    executor dispatch), count only in telemetry.shed, and leave SLA
    accounting untouched."""
    rng = np.random.default_rng(seed)
    tel = Telemetry()
    s = Scheduler(policy, telemetry=tel, max_queue=3, service_ms_est=10.0)
    sizes, prios, slos, arrivals = _random_trace(rng, n)
    shed_tids, admitted = set(), []
    for i in range(n):
        t = s.submit(i, size=int(sizes[i]), priority=int(prios[i]),
                     slo_ms=slos[i], now=float(arrivals[i]))
        if t.shed:
            shed_tids.add(t.tid)
        if rng.random() < 0.3:
            admitted.extend(s.admit(2, now=float(arrivals[i])))
    while s.depth:
        admitted.extend(s.admit(4, now=99.0))
    assert not (shed_tids & {t.tid for t in admitted})
    assert tel.shed == len(shed_tids)
    assert tel.sla_total == 0               # nothing completed yet
    for t in admitted:
        s.complete(t, now=100.0)
    # completions count toward SLA, sheds still only in the shed counter
    assert tel.sla_total == sum(1 for t in admitted
                                if t.deadline_t is not None)
    assert tel.shed == len(shed_tids)


# ---- determinism ----------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 30),
       policy=st.sampled_from(POLICY_NAMES))
def test_admission_deterministic_under_fixed_seed(seed, n, policy):
    """Same trace + same virtual clock => identical admission order."""
    def run():
        rng = np.random.default_rng(seed)
        s = Scheduler(policy, max_queue=n // 2 + 1, service_ms_est=5.0)
        sizes, prios, slos, arrivals = _random_trace(rng, n)
        order = []
        for i in range(n):
            s.submit(i, size=int(sizes[i]), priority=int(prios[i]),
                     slo_ms=slos[i], now=float(arrivals[i]))
            if rng.random() < 0.5:
                order.extend(t.tid for t in s.admit(2,
                                                    now=float(arrivals[i])))
        while s.depth:
            order.extend(t.tid for t in s.admit(3, now=99.0))
        return order

    assert run() == run()


# ---- SLA boundary semantics (regression pin, satellite) -------------------

def test_sla_boundary_exactly_at_deadline_is_a_hit():
    """Pin the boundary the router relies on: finishing exactly AT the
    deadline is a hit; any time past it is a miss; shed tickets appear
    only in the rejection counter, never in misses or latencies."""
    tel = Telemetry()
    s = Scheduler("fifo", telemetry=tel, default_slo_ms=100.0, max_queue=2)
    at = s.submit("at", now=0.0)        # deadline_t = 0.1
    past = s.submit("past", now=0.0)
    shed = s.submit("overflow", now=0.0)
    assert shed.shed and tel.shed == 1
    s.admit(2, now=0.0)
    s.complete(at, now=0.1)             # exactly at the deadline
    s.complete(past, now=0.1 + 1e-6)    # one tick past it
    assert tel.sla_total == 2
    assert tel.sla_misses == 1
    assert len(tel.latencies_ms) == 2   # shed never produced a latency
    assert tel.shed == 1


def test_best_effort_no_slo_never_counts():
    tel = Telemetry()
    s = Scheduler("fifo", telemetry=tel, default_slo_ms=50.0)
    t = s.submit("be", slo_ms=NO_SLO, now=0.0)
    s.admit(1, now=0.0)
    s.complete(t, now=9.0)
    assert tel.sla_total == 0 and tel.sla_misses == 0
    assert tel.served == 1


# ---- chunked-prefill continuations (PR 3) --------------------------------

def _buckets_fn(buckets=(8, 16, 32)):
    return lambda t: pick_bucket(max(t.size, 1), buckets)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 30),
       policy=st.sampled_from(POLICY_NAMES), k=st.integers(1, 4))
def test_conservation_with_continuations_in_flight(seed, n, policy, k):
    """Multiset identity with chunking: a ticket admitted mid-prefill
    re-enters the queue via resubmit; across any interleaving every
    submitted tid still ends up exactly once in {finally-admitted,
    pending, shed}, and the continuation counter equals the number of
    resubmits — no ticket is lost, duplicated, or shed mid-flight."""
    rng = np.random.default_rng(seed)
    tel = Telemetry()
    s = Scheduler(policy, telemetry=tel, max_queue=n)
    sizes, prios, slos, arrivals = _random_trace(rng, n)
    chunks_left = {}                    # tid -> remaining chunks
    submitted, done, shed = [], [], []
    resubmits = 0
    now = 0.0
    for i in range(n):
        now = float(arrivals[i])
        t = s.submit(i, size=int(sizes[i]), priority=int(prios[i]),
                     slo_ms=slos[i], now=now)
        submitted.append(t)
        if t.shed:
            shed.append(t)
        else:
            chunks_left[t.tid] = int(rng.integers(1, 4))
        if rng.random() < 0.5:
            got = s.admit_coherent(k, now=now, bucket_fn=_buckets_fn(),
                                   new_cap=k)
            for g in got:
                chunks_left[g.tid] -= 1
                if chunks_left[g.tid] > 0:
                    s.resubmit(g, size=max(g.size // 2, 1), now=now)
                    resubmits += 1
                else:
                    done.append(g)
    while s.depth:                      # drain, one chunk per round
        now += 0.01
        for g in s.admit_coherent(k, now=now, bucket_fn=_buckets_fn(),
                                  new_cap=k):
            chunks_left[g.tid] -= 1
            if chunks_left[g.tid] > 0:
                s.resubmit(g, size=max(g.size // 2, 1), now=now)
                resubmits += 1
            else:
                done.append(g)
    tids = Counter(t.tid for t in done) + Counter(t.tid for t in shed)
    assert set(tids) == {t.tid for t in submitted}
    assert all(c == 1 for c in tids.values()), "ticket duplicated"
    assert tel.continuations == resubmits
    assert tel.shed == len(shed)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), prio=st.integers(1, 4),
       aging_s=st.floats(0.1, 5.0))
def test_continuation_keeps_priority_and_aging_credit(seed, prio, aging_s):
    """A continuation preserves tid, enqueue_t, priority, and deadline:
    once the original ticket has waited past prio * aging_s, its
    continuation outranks a freshly-arrived priority-0 ticket exactly
    as the original would have — chunking cannot reset the
    bounded-starvation clock."""
    pol = PriorityAgingPolicy(aging_s=aging_s)
    s = Scheduler(pol, default_slo_ms=500.0)
    old = s.submit("old", priority=prio, now=0.0)
    deadline = old.deadline_t
    got = s.admit(1, now=0.1)
    assert got == [old]
    s.resubmit(old, size=7, now=0.2)
    assert old.continuation and old.size == 7
    assert old.enqueue_t == 0.0                 # aging credit preserved
    assert old.deadline_t == deadline           # EDF rank preserved
    now = prio * aging_s * 1.001                # just past the bound
    s.submit("fresh", priority=0, now=now)
    assert [t.payload for t in s.admit(1, now=now)] == ["old"]


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(2, 30),
       k=st.integers(1, 6), cap=st.integers(0, 3))
def test_admit_coherent_is_bucket_pure_and_caps_fresh(seed, n, k, cap):
    """Every coherent group maps to ONE bucket, and at most new_cap of
    its members are fresh (continuations already own a KV slot, fresh
    tickets need a free one)."""
    rng = np.random.default_rng(seed)
    s = Scheduler("fifo")
    bucket_fn = _buckets_fn()
    for i in range(n):
        t = s.submit(i, size=int(rng.integers(1, 40)),
                     now=float(i) * 0.01)
        if rng.random() < 0.3:          # some tickets are continuations
            s.admit(0)                  # no-op, keeps clock semantics
            t.continuation = True
            s.telemetry.record_continuation()
    while s.depth:
        before = s.depth
        group = s.admit_coherent(k, now=99.0, bucket_fn=bucket_fn,
                                 new_cap=cap)
        if not group:
            # only fresh tickets left and cap == 0: nothing admissible
            assert cap == 0
            assert not any(t.continuation for t in s._pending)
            break
        assert len(group) <= k
        assert len({bucket_fn(t) for t in group}) == 1, "bucket impure"
        assert sum(not t.continuation for t in group) <= cap
        assert s.depth == before - len(group)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 30),
       policy=st.sampled_from(POLICY_NAMES))
def test_chunked_admission_deterministic_under_fixed_seed(seed, n, policy):
    """Same trace + same virtual clock => identical coherent-admission
    order, including the resubmit interleavings."""
    def run():
        rng = np.random.default_rng(seed)
        s = Scheduler(policy)
        sizes, prios, slos, arrivals = _random_trace(rng, n)
        order = []
        chunks = {}
        for i in range(n):
            t = s.submit(i, size=int(sizes[i]), priority=int(prios[i]),
                         slo_ms=slos[i], now=float(arrivals[i]))
            chunks[t.tid] = int(rng.integers(1, 3))
            if rng.random() < 0.5:
                for g in s.admit_coherent(2, now=float(arrivals[i]),
                                          bucket_fn=_buckets_fn(),
                                          new_cap=2):
                    order.append(g.tid)
                    chunks[g.tid] -= 1
                    if chunks[g.tid] > 0:
                        s.resubmit(g, now=float(arrivals[i]))
        now = 99.0
        while s.depth:
            now += 0.01
            for g in s.admit_coherent(3, now=now, bucket_fn=_buckets_fn(),
                                      new_cap=3):
                order.append(g.tid)
                chunks[g.tid] -= 1
                if chunks[g.tid] > 0:
                    s.resubmit(g, now=now)
        return order

    assert run() == run()


def test_resubmit_refuses_shed_ticket():
    s = Scheduler("fifo", max_queue=0)
    t = s.submit("x", now=0.0)
    assert t.shed
    with pytest.raises(ValueError):
        s.resubmit(t)


# ---- live service estimation (auto admission calibration) -----------------

def test_auto_estimator_falls_back_until_samples_exist():
    """service_ms_est="auto": static fallback until min_samples, then
    the per-bucket p50 of observed admit->finish service times. A bucket
    with no samples of its own borrows the pooled p50 SIZE-RESCALED from
    the median sampled bucket (PR 9) — the old raw pooled borrow priced
    a 512-token prefill off a 32-token sample set."""
    s = Scheduler("fifo", service_ms_est="auto", service_ms_fallback=20.0)
    assert s.service_ms_for(10) == 20.0          # fallback seeds the check
    for i in range(5):
        t = s.submit(i, size=10, now=float(i))
        s.admit(1, now=float(i))
        s.complete(t, now=float(i) + 0.05)       # 50 ms service each
    assert s.service_ms_for(10) == pytest.approx(50.0)
    # a cold bucket borrows the pooled p50 rescaled from the anchor
    # bucket (32, where every sample lives) to its own size: with no
    # perf model wired the prior is linear, 50ms * 512/32
    assert s.service_ms_for(400) == pytest.approx(50.0 * 512 / 32)


def test_auto_estimator_none_without_fallback_means_no_shedding():
    s = Scheduler("fifo", service_ms_est="auto", default_slo_ms=0.001)
    t = s.submit("tight", now=0.0)               # absurdly tight deadline
    assert not t.shed                            # no estimate -> no check


def test_auto_estimator_sheds_like_static_once_calibrated():
    """Once calibrated, the feasibility check sheds a ticket whose slack
    cannot cover the queue ahead at the measured per-bucket p50."""
    s = Scheduler("fifo", service_ms_est="auto")
    for i in range(5):
        t = s.submit(i, size=8, now=float(i))
        s.admit(1, now=float(i))
        s.complete(t, now=float(i) + 0.1)        # 100 ms per ticket
    for i in range(3):                           # 3 pending ahead
        s.submit(f"p{i}", size=8, now=10.0)
    ok = s.submit("roomy", size=8, slo_ms=1_000.0, now=10.0)
    tight = s.submit("tight", size=8, slo_ms=150.0, now=10.0)
    assert not ok.shed
    assert tight.shed                            # needs ~500ms, has 150
    assert s.service_ms_for(8) == pytest.approx(100.0)


def test_rejects_unknown_service_est_string():
    with pytest.raises(ValueError):
        Scheduler("fifo", service_ms_est="fast")


# ---- router balance -------------------------------------------------------

from conftest import StubReplica as _StubReplica  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n_replicas=st.integers(2, 6),
       n=st.integers(1, 60))
def test_router_pure_submit_spread_bounded_by_one(seed, n_replicas, n):
    """From an empty fleet, any all-submit sequence lands every ticket on
    a current-minimum replica, so the routed-count spread never exceeds
    1 — the provable balance bound."""
    router = ReplicaRouter([_StubReplica() for _ in range(n_replicas)])
    rng = np.random.default_rng(seed)
    for i in range(n):
        router.submit(i, slo_ms=float(rng.uniform(10, 100))
                      if rng.random() < 0.5 else None)
        assert spread(router) <= 1
        assert router.shed == 0


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n_replicas=st.integers(2, 5),
       n=st.integers(1, 60))
def test_router_always_picks_a_min_load_replica(seed, n_replicas, n):
    """Even with random draining interleaved, every submit lands on a
    replica whose load was minimal at that instant."""
    router = ReplicaRouter([_StubReplica() for _ in range(n_replicas)])
    rng = np.random.default_rng(seed)
    for i in range(n):
        if rng.random() < 0.4:          # drain a random replica a bit
            r = router.replicas[int(rng.integers(n_replicas))]
            if r.has_work:
                r.step_once()
        loads = [router.load(j) for j in range(n_replicas)]
        before = list(router.routed)
        router.submit(i)
        j = next(j for j in range(n_replicas)
                 if router.routed[j] != before[j])
        assert loads[j] == min(loads), \
            f"routed to load {loads[j]}, min was {min(loads)}"


# ---- per-slot sequence state (PR 5) ---------------------------------------

from repro.serving.state import (SequenceStateManager,  # noqa: E402
                                 require_chunkable)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), slots=st.integers(1, 6),
       n_ops=st.integers(1, 120))
def test_sequence_state_partition_invariant(seed, slots, n_ops):
    """Slot conservation through ANY lifecycle interleaving: at every
    instant the slots partition into exactly free | active | prefilling
    (pairwise disjoint, union = all slots), a parked ticket gets ITS OWN
    slot back on re-acquire, and evict_all returns every slot-holding
    ticket exactly once and resets to all-free."""
    from repro.serving.scheduler import Ticket
    rng = np.random.default_rng(seed)
    mgr = SequenceStateManager(slots)
    held = {}                      # id(ticket) -> (ticket, slot, state)
    next_tid = 0
    for _ in range(n_ops):
        op = rng.integers(0, 5)
        if op == 0 and mgr.free_count:              # fresh acquire
            t = Ticket(next_tid, None)
            next_tid += 1
            s = mgr.acquire(t)
            if rng.random() < 0.5:
                mgr.activate(t, s, int(rng.integers(1, 64)))
                held[id(t)] = (t, s, "active")
            else:
                mgr.park(t, s)
                held[id(t)] = (t, s, "prefilling")
        elif op == 1:                               # continuation chunk
            parked = [(t, s) for t, s, st_ in held.values()
                      if st_ == "prefilling"]
            if parked:
                t, s = parked[int(rng.integers(len(parked)))]
                got = mgr.acquire(t)
                assert got == s, "continuation lost its own slot"
                mgr.activate(t, got, int(rng.integers(1, 64)))
                held[id(t)] = (t, got, "active")
        elif op == 2:                               # completion
            act = [(t, s) for t, s, st_ in held.values() if st_ == "active"]
            if act:
                t, s = act[int(rng.integers(len(act)))]
                mgr.release(s)
                del held[id(t)]
        elif op == 3:                               # steal-veto spot check
            t = Ticket(next_tid, None)
            next_tid += 1
            assert mgr.steal_eligible(t)            # fresh: stealable
            for ht, hs, st_ in held.values():
                if st_ == "prefilling":
                    assert not mgr.steal_eligible(ht)
        else:                                       # fault drain
            evicted = mgr.evict_all()
            active_held = [t for t, _, st_ in held.values()
                           if st_ == "active"]
            assert sorted(id(t) for t in evicted) \
                == sorted(id(t) for t in active_held)
            assert mgr.free_count == slots and mgr.inflight == 0
            held.clear()
        mgr.check_partition()
        assert mgr.inflight == len(held)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), slots=st.integers(1, 6),
       n_ops=st.integers(1, 120))
def test_partition_invariant_with_paging_and_migration(seed, slots, n_ops):
    """PR 8 partition moves compose with the PR 5 lifecycle: random
    interleavings of acquire / park / activate / release with
    ``page_out`` (active -> free, ticket leaves to the engine's paged
    store), its fault-back re-``acquire`` + ``activate`` at the parked
    position, and ``release_prefilling`` (migration-out: prefilling ->
    free) keep the partition exact at every step — and after
    ``evict_all`` a restore of the evicted sessions rebuilds the exact
    free/active/prefilling split."""
    from repro.serving.scheduler import Ticket
    rng = np.random.default_rng(seed)
    mgr = SequenceStateManager(slots)
    held = {}                     # id(ticket) -> (ticket, slot, state)
    paged = []                    # (ticket, pos) — engine-side paged store
    next_tid = 0
    for _ in range(n_ops):
        op = rng.integers(0, 7)
        if op == 0 and mgr.free_count:              # fresh acquire
            t = Ticket(next_tid, None)
            next_tid += 1
            s = mgr.acquire(t)
            if rng.random() < 0.5:
                mgr.activate(t, s, int(rng.integers(1, 64)))
                held[id(t)] = (t, s, "active")
            else:
                mgr.park(t, s)
                held[id(t)] = (t, s, "prefilling")
        elif op == 1:                               # continuation chunk
            parked = [(t, s) for t, s, st_ in held.values()
                      if st_ == "prefilling"]
            if parked:
                t, s = parked[int(rng.integers(len(parked)))]
                assert mgr.acquire(t) == s
                mgr.activate(t, s, int(rng.integers(1, 64)))
                held[id(t)] = (t, s, "active")
        elif op == 2:                               # completion
            act = [(t, s) for t, s, st_ in held.values() if st_ == "active"]
            if act:
                t, s = act[int(rng.integers(len(act)))]
                mgr.release(s)
                del held[id(t)]
        elif op == 3:                               # page-out (PR 8)
            act = [(t, s) for t, s, st_ in held.values() if st_ == "active"]
            if act:
                t, s = act[int(rng.integers(len(act)))]
                pos = int(mgr.pos[s])
                got = mgr.page_out(s)
                assert got is t, "page_out returned the wrong ticket"
                paged.append((t, pos))
                del held[id(t)]
        elif op == 4 and paged and mgr.free_count:  # fault-back (PR 8)
            t, pos = paged.pop(0)
            s = mgr.acquire(t)
            mgr.activate(t, s, pos)
            assert int(mgr.pos[s]) == pos           # resumes where parked
            held[id(t)] = (t, s, "active")
        elif op == 5:                               # migration-out (PR 8)
            parked = [(t, s) for t, s, st_ in held.values()
                      if st_ == "prefilling"]
            if parked:
                t, s = parked[int(rng.integers(len(parked)))]
                assert mgr.release_prefilling(t) == s
                del held[id(t)]                     # left with its snapshot
        else:                                       # evict + exact restore
            evicted = mgr.evict_all()
            assert sorted(id(t) for t in evicted) == sorted(
                id(t) for t, _, st_ in held.values() if st_ == "active")
            assert mgr.free_count == slots and mgr.inflight == 0
            # restore every evicted session into fresh slots: the
            # partition must come back exactly as large as before
            restored = 0
            for t in evicted:
                if not mgr.free_count:
                    break
                s = mgr.acquire(t)
                mgr.activate(t, s, int(rng.integers(1, 64)))
                restored += 1
            held = {id(t): (t, s, "active")
                    for s, t in mgr.active.items()}
            assert len(held) == restored == len(evicted)
        mgr.check_partition()
        assert mgr.inflight == len(held)
        # a paged ticket holds NO slot: it must be invisible to the
        # partition and fresh-stealable only via the engine's veto,
        # not the manager's
        for t, _ in paged:
            assert id(t) not in mgr.prefilling


def test_require_chunkable_names_offending_kind():
    """The capability check replacing the all-global gate: every
    state-carrying kind passes; encoder-decoder raises naming the
    cross-attention decoder kind."""
    from repro.configs import get_config, reduce_for_smoke
    for arch in ("deepseek-7b", "gemma2-27b", "mamba2-130m",
                 "recurrentgemma-9b"):
        require_chunkable(reduce_for_smoke(get_config(arch)))  # no raise
    with pytest.raises(ValueError, match="decoder"):
        require_chunkable(reduce_for_smoke(get_config("whisper-medium")))


# ---- cross-replica work stealing + fault drain (PR 4) ---------------------

from fleet_sim import FleetSim, random_schedule, run_to_completion  # noqa: E402


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n_replicas=st.integers(2, 4),
       n_ops=st.integers(5, 120), steal=st.booleans(), fail=st.booleans(),
       policy=st.sampled_from(POLICY_NAMES))
def test_fleet_conservation_under_steal_and_fault(seed, n_replicas, n_ops,
                                                  steal, fail, policy):
    """Fleet-wide multiset identity through ANY seeded interleaving of
    submit (hot-keyed skew), virtual ticks, stealing rounds, and a
    mid-run replica kill: submitted = completed + pending-anywhere +
    shed, with no ticket duplicated across queues — and after the drain
    every accepted ticket still completes."""
    sim = FleetSim(replicas=n_replicas, seed=seed, steal=steal,
                   policy=policy, slots=1 + seed % 2,
                   service_s=[0.004 * (1 + i) for i in range(n_replicas)],
                   max_queue=12)
    failed = random_schedule(sim, n_ops, skew=0.5, hot=0, max_priority=2,
                             fail_at=n_ops // 2 if fail else -1)
    run_to_completion(sim)
    note(f"failed={failed} shed={len(sim.shed)} "
         f"steals={sum(sim.router.steals_per_replica)}")
    sim.assert_conserved()
    assert len(sim.completed) == sum(1 for t in sim.submitted if not t.shed)
    if failed >= 0:
        assert not sim.replicas[failed].has_work


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n_replicas=st.integers(2, 4),
       n_ops=st.integers(5, 120), steal=st.booleans(), fail=st.booleans(),
       policy=st.sampled_from(POLICY_NAMES))
def test_fleet_conservation_under_paging_and_migration(seed, n_replicas,
                                                       n_ops, steal, fail,
                                                       policy):
    """PR 8 acceptance: the conservation identity survives random
    page-out / page-in / migrate events interleaved with submits, ticks,
    steal rounds, and a mid-run kill — submitted = completed +
    pending-anywhere + shed, nothing duplicated, paged sessions included
    in pending — and every accepted ticket still completes after the
    drain (a paged or migrated session is never stranded)."""
    sim = FleetSim(replicas=n_replicas, seed=seed, steal=steal,
                   policy=policy, slots=2 + seed % 2,
                   service_s=[0.004 * (1 + i) for i in range(n_replicas)],
                   max_queue=12)
    failed = random_schedule(sim, n_ops, skew=0.5, hot=0, max_priority=2,
                             fail_at=n_ops // 2 if fail else -1,
                             p_page=0.3, p_migrate=0.2)
    run_to_completion(sim)
    tel = sim.router.fleet_telemetry()
    note(f"failed={failed} shed={len(sim.shed)} paged_out={tel.paged_out} "
         f"migrated={tel.migrated}")
    sim.assert_conserved()
    assert len(sim.completed) == sum(1 for t in sim.submitted if not t.shed)
    # every fault-back had a park; the shortfall is sessions that
    # completed-by-drain or died with a failed card while still paged
    assert tel.paged_in <= tel.paged_out
    if failed >= 0:
        assert not sim.replicas[failed].has_work


def test_migrated_ticket_keeps_credit_and_remaining_service():
    """Sim-level migration contract: the moved ticket keeps tid /
    priority / deadline untouched (shared virtual clock — no restamp),
    its frozen remaining service resumes on the destination (no
    restart-from-zero), and the move lands in ``migrated``, not
    ``steals``."""
    sim = FleetSim(replicas=2, seed=0, steal=False, slots=1,
                   service_s=0.01, dt=0.005)
    t = sim.submit(size=4, priority=3, slo_ms=500.0, pin=0)
    tid, prio, deadline = t.tid, t.priority, t.deadline_t
    sim.tick()                                  # admit: due at now+0.01
    (tkt, due), = sim.replicas[0].active
    assert tkt is t
    moved = sim.migrate(0, 1)
    assert moved == 1
    assert not sim.replicas[0].active
    (tkt2, due2), = sim.replicas[1].active
    assert tkt2 is t
    assert t.tid == tid and t.priority == prio and t.deadline_t == deadline
    # frozen remaining service: the due time is preserved exactly
    # (migrate() re-bases from now, and now hasn't advanced)
    assert due2 == pytest.approx(due)
    tel = sim.router.fleet_telemetry()
    assert tel.migrated == 1 and tel.steals == 0
    run_to_completion(sim)
    sim.assert_conserved()
    assert t in sim.completed


def test_page_out_round_trip_preserves_remaining_service():
    """A page-out/page-in round trip at the sim level loses no progress:
    remaining service is frozen while parked and resumes exactly."""
    sim = FleetSim(replicas=1, seed=0, steal=False, slots=1,
                   service_s=0.1, dt=0.005)
    t = sim.submit(size=4, pin=0)
    sim.tick()                                  # due at 0.005 + 0.1
    (_, due), = sim.replicas[0].active
    remaining_before = due - sim.now
    assert sim.page_out(0) is t
    for _ in range(10):                         # parked: the clock runs on
        sim.now += sim.dt
    # the auto fault-back path is step(); here exercise the explicit op
    assert sim.page_in(0) is t
    (_, due2), = sim.replicas[0].active
    assert due2 - sim.now == pytest.approx(remaining_before)
    run_to_completion(sim)
    sim.assert_conserved()
    tel = sim.router.fleet_telemetry()
    assert tel.paged_out == 1 and tel.paged_in == 1


@settings(max_examples=25, deadline=None)
@given(prio=st.integers(1, 4), aging_s=st.floats(0.1, 5.0),
       clock_skew=st.floats(0.0, 3.0))
def test_stolen_ticket_keeps_credit_and_is_never_a_continuation(
        prio, aging_s, clock_skew):
    """The re-stamping contract: a stolen ticket keeps tid / priority /
    deadline, its AGE (aging credit) survives even a cross-timeline move
    (rebase_pending-style accounting shifts enqueue/deadline by the
    clock delta, preserving age and slack exactly), and a continuation
    is never handed to the thief — it owns a KV slot at home."""
    pol = PriorityAgingPolicy(aging_s=aging_s)
    victim = Scheduler(pol, default_slo_ms=5_000.0)
    old = victim.submit("old", priority=prio, now=0.0)
    tid, deadline = old.tid, old.deadline_t
    cont = victim.submit("cont", priority=0, now=0.0)
    assert victim.admit(1, now=0.0) == [cont]   # rank 0 beats rank prio
    victim.resubmit(cont, now=0.01)             # now a continuation
    t_steal = prio * aging_s * 1.001            # just past the aging bound
    stolen = victim.steal_pending(5, now=t_steal)
    assert stolen == [old], "steal must skip the continuation"
    assert victim.depth == 1 and victim._pending[0] is cont
    thief = Scheduler(PriorityAgingPolicy(aging_s=aging_s))
    thief_now = t_steal + clock_skew            # thief's own timeline
    thief.absorb(stolen, now=thief_now, from_now=t_steal)
    assert old.tid == tid and old.priority == prio and old.stolen
    # age preserved exactly across the timeline shift...
    assert old.age(thief_now) == pytest.approx(t_steal)
    # ...and so is deadline slack (EDF rank survives the move)
    assert old.deadline_t - thief_now == pytest.approx(deadline - t_steal)
    thief.submit("fresh", priority=0, now=thief_now)
    # past the aging bound, the stolen ticket still outranks fresh class-0
    assert [t.payload for t in thief.admit(1, now=thief_now)] == ["old"]
    assert thief.telemetry.steals == 1


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n_replicas=st.integers(2, 4),
       n_ops=st.integers(5, 80))
def test_stealing_deterministic_under_fixed_seed(seed, n_replicas, n_ops):
    """Same seed => identical completion order, steal attribution, and
    routing — the whole steal schedule is a pure function of the seed."""
    def run():
        sim = FleetSim(replicas=n_replicas, seed=seed, steal=True,
                       service_s=[0.003 * (1 + i)
                                  for i in range(n_replicas)])
        random_schedule(sim, n_ops, skew=0.6, hot=0)
        order = run_to_completion(sim)
        return (order, list(sim.router.steals_per_replica),
                list(sim.router.routed))

    assert run() == run()


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n_replicas=st.integers(2, 4),
       n=st.integers(1, 40), ticks=st.integers(0, 5),
       fail_idx=st.integers(0, 3))
def test_drain_rehomes_every_pending_ticket_exactly_once(seed, n_replicas,
                                                         n, ticks, fail_idx):
    """drain_replica moves the dead replica's whole outstanding load onto
    live queues with no loss and no duplication, counts it in the
    victim's drained counter, and is idempotent."""
    assume(fail_idx < n_replicas)               # exercises the shim too
    sim = FleetSim(replicas=n_replicas, seed=seed, steal=False)
    for _ in range(n):
        sim.submit(pin=fail_idx)
    for _ in range(ticks):
        sim.tick()
    before = Counter(sim.pending_payloads())
    victim = sim.replicas[fail_idx]
    outstanding = victim.scheduler.depth + victim.inflight
    moved = sim.fail(fail_idx)
    assert moved == outstanding
    assert victim.scheduler.depth == 0 and victim.inflight == 0
    assert Counter(sim.pending_payloads()) == before   # exactly once each
    assert victim.telemetry.drained == moved
    assert sim.fail(fail_idx) == 0              # idempotent
    note(f"moved={moved} after {ticks} ticks")
    run_to_completion(sim)
    sim.assert_conserved()


# ---- steal-aware feedback routing (PR 5) ----------------------------------

def test_feedback_steal_share_is_time_proportional():
    """ROADMAP open item closed: with route="feedback" + steal=True the
    stolen share follows the thief/victim EWMA step-time ratio. A
    3x-faster thief (EWMA 0.01 vs 0.03) takes r/(1+r) = 3/4 of the
    victim's un-startable backlog — ~3x the tickets the victim keeps —
    and fleet-wide conservation holds across the move."""
    sim = FleetSim(replicas=2, service_s=[0.03, 0.01], slots=[1, 16],
                   steal=True, route="feedback", seed=0)
    for _ in range(13):
        sim.submit(pin=0)                   # hot-keyed: all on the slow card
    backlog = sim.replicas[0].scheduler.fresh_depth - 1   # 1 startable
    assert backlog == 12
    moved = sim.router.maybe_steal(now=sim.now)
    assert moved == 9                       # round(12 * 3/4)
    kept = sim.replicas[0].scheduler.fresh_depth - 1
    assert moved == 3 * kept                # ~3x the tickets the victim keeps
    assert sim.replicas[0].scheduler.depth \
        + sim.replicas[1].scheduler.depth == 13           # conservation
    sim.assert_conserved()
    run_to_completion(sim)
    sim.assert_conserved()
    assert len(sim.completed) == 13


def test_count_mode_steal_share_stays_half():
    """Without feedback routing the share stays count-half (the PR 4
    contract is unchanged)."""
    sim = FleetSim(replicas=2, service_s=[0.03, 0.01], slots=[1, 16],
                   steal=True, route="count", seed=0)
    for _ in range(13):
        sim.submit(pin=0)
    assert sim.router.maybe_steal(now=sim.now) == 6       # 12 // 2
    run_to_completion(sim)
    sim.assert_conserved()


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), backlog=st.integers(2, 60),
       ratio=st.floats(0.2, 8.0))
def test_feedback_steal_share_bounds_and_conservation(seed, backlog, ratio):
    """Property: under feedback routing the stolen count equals
    min(cap, max(round(backlog * r / (1+r)), 1)) for speed ratio r, never
    exceeds the thief's free slots, and no ticket is lost or duplicated
    by the move."""
    victim_s = 0.01 * ratio
    sim = FleetSim(replicas=2, service_s=[victim_s, 0.01],
                   slots=[1, backlog + 4], steal=True, route="feedback",
                   seed=seed)
    for _ in range(backlog + 1):            # 1 startable + ``backlog`` stuck
        sim.submit(pin=0)
    moved = sim.router.maybe_steal(now=sim.now)
    want = max(int(round(backlog * ratio / (1.0 + ratio))), 1)
    note(f"backlog={backlog} ratio={ratio:.2f} moved={moved} want={want}")
    assert moved == min(backlog + 4, want)
    assert sim.replicas[0].scheduler.depth \
        + sim.replicas[1].scheduler.depth == backlog + 1
    sim.assert_conserved()


# ---- mixed-precision fleet (quantized serving) ----------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 50),
       n_fp32=st.integers(1, 2), n_int8=st.integers(1, 2))
def test_class0_never_lands_on_int8_while_fp32_lives(seed, n, n_fp32,
                                                     n_int8):
    """The precision-pin invariant: in a mixed fleet with live fp32
    replicas, EVERY priority-0 submit lands on an fp32 replica no matter
    how load skews (draining interleaved); bulk traffic flows freely and
    no downgrade is ever counted while fp32 capacity exists."""
    precisions = ["fp32"] * n_fp32 + ["w8a8"] * n_int8
    router = ReplicaRouter([_StubReplica(precision=p) for p in precisions])
    rng = np.random.default_rng(seed)
    for i in range(n):
        prio = int(rng.integers(0, 2))
        before = list(router.routed)
        router.submit(i, priority=prio)
        j = next(k for k in range(len(precisions))
                 if router.routed[k] != before[k])
        if prio == 0:
            assert precisions[j] == "fp32", \
                f"class-0 ticket routed to {precisions[j]} replica {j}"
        if rng.random() < 0.3:              # drain someone: loads vary
            r = router.replicas[int(rng.integers(len(precisions)))]
            if r.has_work:
                r.step_once()
    assert router.fleet_telemetry().precision_rehomed == 0


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), backlog=st.integers(2, 30))
def test_int8_thief_never_steals_class0_while_fp32_lives(seed, backlog):
    """Stealing respects the precision pin: an int8 thief pulling from a
    backlogged fp32 sibling (fp32 still live) only takes priority>0
    tickets — accuracy-pinned work stays on the fp32 card — and
    conservation holds through the move and the drain."""
    sim = FleetSim(replicas=2, service_s=[0.03, 0.01],
                   slots=[1, backlog + 2], steal=True,
                   precisions=["fp32", "w8a8"], seed=seed)
    rng = np.random.default_rng(seed)
    for _ in range(backlog + 1):            # 1 startable + backlog stuck
        sim.submit(priority=int(rng.integers(0, 3)), pin=0)
    moved = sim.router.maybe_steal(now=sim.now)
    stolen = [t for t in sim.replicas[1].scheduler._pending if t.stolen]
    assert len(stolen) == moved
    assert all(t.priority > 0 for t in stolen), \
        "int8 thief stole accuracy-pinned class-0 work"
    sim.assert_conserved()
    run_to_completion(sim)
    sim.assert_conserved()
    assert len(sim.completed) == backlog + 1


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 30),
       ticks=st.integers(0, 4))
def test_drain_of_last_fp32_rehomes_class0_to_int8_and_counts(seed, n,
                                                              ticks):
    """Graceful degradation of the pin: killing the LAST fp32 replica
    re-homes its whole outstanding load to the int8 survivor — class-0
    included, each downgrade counted in the receiver's
    precision_rehomed — and every accepted ticket still completes."""
    sim = FleetSim(replicas=2, precisions=["fp32", "w8a8"], steal=False,
                   seed=seed)
    rng = np.random.default_rng(seed)
    for _ in range(n):
        sim.submit(priority=int(rng.integers(0, 2)), pin=0)
    for _ in range(ticks):
        sim.tick()
    victim = sim.replicas[0]
    outstanding = victim.scheduler.depth + victim.inflight
    high_outstanding = \
        sum(t.priority == 0 for t in victim.scheduler._pending) \
        + sum(t.priority == 0 for t, _ in victim.active)
    moved = sim.fail(0)
    assert moved == outstanding
    assert sim.replicas[1].telemetry.precision_rehomed == high_outstanding
    sim.assert_conserved()
    run_to_completion(sim)
    sim.assert_conserved()
    assert len(sim.completed) == n          # nothing lost to the degrade


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 40))
def test_router_shed_counted_separately(seed, n):
    """Fleet-level shed accounting: shed tickets increment router.shed +
    fleet telemetry.shed, and never count as routed."""
    router = ReplicaRouter([_StubReplica(max_queue=2) for _ in range(2)])
    rng = np.random.default_rng(seed)
    shed = 0
    for i in range(n):
        t = router.submit(i)
        shed += t.shed
        if rng.random() < 0.3:
            for r in router.replicas:
                if r.has_work:
                    r.step_once()
    assert router.shed == shed
    assert router.fleet_telemetry().shed == shed
    assert sum(router.routed) == n - shed


# ---------------------------------------------------------------------------
# Elastic fleet controller (PR 7): scale events ride the SAME drain/absorb
# machinery, so the fleet-wide invariants must survive the controller
# interleaving scale-up / scale-down / fault-drain with serving.
# ---------------------------------------------------------------------------

from fleet_sim import make_controller  # noqa: E402
from repro.serving.fleet_sim import (flash_crowd_trace,  # noqa: E402
                                     multi_tenant_trace, run_elastic)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(20, 250),
       crowd_x=st.floats(1.0, 8.0), kill=st.booleans())
def test_fleet_conservation_across_scale_events(seed, n, crowd_x, kill):
    """Ticket conservation holds across ANY interleaving of submit /
    steal / scale-up / scale-down / missed-heartbeat drain: accepted =
    completed exactly, nothing duplicated (run_elastic asserts the
    multiset identity fleet-wide on exit)."""
    sim = FleetSim(replicas=2, service_s=0.01, slots=1, dt=0.005,
                   seed=seed, max_queue=16)
    ctl = make_controller(sim, min_replicas=1, max_replicas=5)
    arr = flash_crowd_trace(n, base_gap_s=0.006, crowd_x=crowd_x,
                            seed=seed, slo_ms=500.0)
    kills = [(arr[n // 2].t, 0)] if kill else []
    m = run_elastic(sim, ctl, arr, kills=kills)
    assert m["lost"] == 0
    assert m["accepted"] == m["completed"]
    assert m["submitted"] == m["completed"] + m["shed"]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(20, 150))
def test_controller_never_drains_last_live_replica(seed, n):
    """However the load and faults land, the router keeps >= 1 live
    replica: deliberate scale-down is refused at min_replicas, and a
    fault on the last live replica goes replace-then-drain."""
    sim = FleetSim(replicas=2, service_s=0.01, slots=1, dt=0.005,
                   seed=seed, max_queue=16)
    ctl = make_controller(sim, min_replicas=1, max_replicas=4)
    arr = flash_crowd_trace(n, base_gap_s=0.01, crowd_x=2.0, seed=seed)
    # both initial replicas die, well apart (detection is ~timeout_s)
    m = run_elastic(sim, ctl, arr, kills=[(arr[n // 3].t, 0),
                                          (arr[(2 * n) // 3].t, 1)])
    assert len(sim.router.alive) >= 1
    for d in ctl.decisions:
        if d.action == "down":
            assert d.live >= 1
    assert m["lost"] == 0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_scale_down_never_burns_last_fp32(seed):
    """While mixed-precision class-0 pinning is active, deliberate
    scale-down never chooses the last live fp32 replica, no matter how
    deep the trough — the accuracy pin survives autoscaling."""
    sim = FleetSim(replicas=3, precisions=["fp32", "w8a8", "w8a8"],
                   service_s=0.01, slots=1, dt=0.005, seed=seed,
                   max_queue=16)
    ctl = make_controller(sim, min_replicas=1, max_replicas=3)
    arr = multi_tenant_trace(120, base_gap_s=0.05, seed=seed)  # light
    m = run_elastic(sim, ctl, arr)
    assert ctl.scale_downs >= 1         # the trough did shrink the fleet
    assert len(sim.router.fp32_alive) >= 1
    # replica 0 is the ONLY fp32 here (no scale-ups under this load), so
    # no deliberate down may ever have chosen it
    assert all(d.replica != 0 for d in ctl.decisions
               if d.action == "down")
    assert m["lost"] == 0


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_controller_decision_log_deterministic(seed):
    """Fixed seed -> bit-identical decision log and fleet outcome: the
    controller is a pure function of (router state, telemetry, clock)."""
    def one():
        sim = FleetSim(replicas=2, service_s=0.01, slots=1, dt=0.005,
                       seed=seed, max_queue=16)
        ctl = make_controller(sim, min_replicas=1, max_replicas=5)
        arr = flash_crowd_trace(150, base_gap_s=0.006, crowd_x=5.0,
                                seed=seed, slo_ms=500.0)
        m = run_elastic(sim, ctl, arr, kills=[(arr[75].t, 0)])
        return ([(d.now, d.action, d.replica, d.live, d.reason)
                 for d in ctl.decisions],
                m["completed"], m["shed"], m["replica_ticks"])
    assert one() == one()


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), crowd_x=st.floats(1.0, 10.0))
def test_scale_decisions_respect_cooldown(seed, crowd_x):
    """Hysteresis no-flap: any two scale decisions (and any scale
    decision after a fault drain) are >= cooldown_s apart — the fleet
    can never thrash faster than the cooldown window."""
    cool = 0.3
    sim = FleetSim(replicas=2, service_s=0.01, slots=1, dt=0.005,
                   seed=seed, max_queue=16)
    ctl = make_controller(sim, min_replicas=1, max_replicas=6,
                          cooldown_s=cool)
    arr = flash_crowd_trace(200, base_gap_s=0.006, crowd_x=crowd_x,
                            seed=seed)
    run_elastic(sim, ctl, arr, kills=[(arr[100].t, 0)])
    events = [d for d in ctl.decisions
              if d.action in ("up", "down", "replace", "drain_failed")]
    for prev, cur in zip(events, events[1:]):
        if cur.action in ("up", "down"):
            assert cur.now - prev.now >= cool - 1e-9, (
                f"{cur.action} at {cur.now} only "
                f"{cur.now - prev.now:.3f}s after {prev.action}")
