"""core/ package tests: partitioner (T1/T8), bucketing (T5), transfers (T6),
host split (T7), pipeline (T2), metrics, numerics harness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bucketing as B
from repro.core import host_split as HS
from repro.core import metrics as MET
from repro.core import partitioner as PT
from repro.core import transfer as TR
from repro.core.numerics import GoldenSet
from repro.core.pipeline import TwoStagePipeline, steady_state_speedup


# ---- partitioner ---------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(n=st.integers(4, 48), shards=st.sampled_from([2, 4, 8]),
       seed=st.integers(0, 10**6))
def test_partition_assigns_every_table_once(n, shards, seed):
    rng = np.random.default_rng(seed)
    rows = rng.integers(100, 10000, n).tolist()
    looks = rng.integers(1, 64, n).tolist()
    asn = PT.partition_tables(rows, shards, looks)
    assert sorted(t for ts in asn.tables_of_shard for t in ts) == list(range(n))
    # every table's rows fit inside its shard's slab range
    for t in range(n):
        s = asn.shard_of_table[t]
        lo, hi = s * asn.rows_per_shard, (s + 1) * asn.rows_per_shard
        assert lo <= asn.table_offset[t]
        assert asn.table_offset[t] + rows[t] <= hi


def test_length_aware_beats_naive_on_skew():
    """Paper §VI-B: 15-34% SLS latency reduction with length info. Skewed
    workload: big tables with few lookups, small hot tables."""
    rng = np.random.default_rng(7)
    rows = [10_000_000] * 8 + [10_000] * 24
    looks = [1] * 8 + list(rng.integers(40, 80, 24))
    rep = PT.balance_report(rows, looks, num_shards=6)
    assert rep["latency_reduction"] > 0.15, rep
    assert rep["aware_imbalance"] < rep["naive_imbalance"]


def test_allocate_cores_matches_paper_ratio():
    """With sparse ~= half of dense cost, ~1/3 of cores go to SLS (paper)."""
    cs, t = PT.allocate_cores(sparse_cost=1.0, dense_cost=2.0, num_cores=12)
    assert cs == 4
    assert t == pytest.approx(0.25)


# ---- bucketing -----------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(l=st.integers(1, 600))
def test_pick_bucket_covers(l):
    b = B.pick_bucket(l, B.DEFAULT_BUCKETS)
    if l <= max(B.DEFAULT_BUCKETS):
        assert b >= l
        smaller = [x for x in B.DEFAULT_BUCKETS if x >= l]
        assert b == min(smaller)
    else:
        assert b == max(B.DEFAULT_BUCKETS)


def test_bucketed_executable_compiles_once_per_bucket():
    calls = []

    def build(bucket):
        calls.append(bucket)
        return lambda toks, mask: toks.shape
    ex = B.BucketedExecutable(build, buckets=(8, 16, 32))
    seqs = [np.arange(5), np.arange(7)]
    assert ex(seqs) == (2, 8)
    assert ex([np.arange(6)]) == (1, 8)
    assert ex([np.arange(20)]) == (1, 32)
    assert calls == [8, 32]
    assert ex.compile_count == 2


def test_length_sorted_batching_cuts_waste():
    rng = np.random.default_rng(0)
    lengths = rng.lognormal(3.2, 0.8, 512).astype(int).clip(4, 512).tolist()
    naive = B.wasted_compute_fraction(lengths, B.DEFAULT_BUCKETS)
    batches = B.length_sorted_batches(lengths, 16)
    sorted_waste = np.mean([
        B.wasted_compute_fraction([max(lengths[i] for i in b)] * len(b),
                                  B.DEFAULT_BUCKETS)
        - (1 - np.mean([lengths[i] for i in b])
           / max(lengths[i] for i in b)) * 0
        for b in batches])
    # grouping similar lengths shouldn't increase padding waste
    assert sorted_waste <= naive + 0.25


# ---- transfers -----------------------------------------------------------

def test_partial_transfer_roundtrip(rng):
    bags = [[[int(x) for x in rng.integers(0, 100, rng.integers(0, 5))]
             for _ in range(6)] for _ in range(4)]
    sb = TR.pack_sparse_inputs(bags, num_tables=6, max_lookups=8)
    stats = TR.TransferStats()
    idx, lens = TR.command_batched_transfer(sb, stats)
    np.testing.assert_array_equal(np.asarray(idx), sb.indices)
    np.testing.assert_array_equal(np.asarray(lens), sb.lengths)
    assert stats.bytes_partial < stats.bytes_full
    assert stats.num_transfers_batched < stats.num_transfers_naive


def test_partial_transfer_saves_most_bytes_when_sparse(rng):
    bags = [[[1] for _ in range(16)] for _ in range(8)]   # 1 of 64 slots used
    sb = TR.pack_sparse_inputs(bags, num_tables=16, max_lookups=64)
    stats = TR.TransferStats()
    TR.command_batched_transfer(sb, stats)
    assert stats.bytes_saved_frac > 0.9


# ---- host split ----------------------------------------------------------

def test_split_keeps_unsupported_on_host():
    ops = [HS.OpSpec("tokenize", 1e3, 100, 400, supported_on_device=False),
           HS.OpSpec("embed", 1e9, 400, 4000),
           HS.OpSpec("transformer", 1e12, 4000, 4000)]
    dec = HS.split_net(ops)
    assert "tokenize" in dec.host_ops
    assert "transformer" in dec.device_ops


def test_broadcast_policy_prefers_concat_single_broadcast():
    res = HS.broadcast_placement(num_tables=100, row_bytes=256, batch=64)
    assert res["concat_then_single_broadcast"] < res["host_broadcast"]
    assert res["concat_then_single_broadcast"] \
        <= res["device_broadcast_per_table"]


# ---- pipeline ------------------------------------------------------------

def test_pipeline_preserves_outputs():
    sparse = jax.jit(lambda x: x * 2.0)
    dense = jax.jit(lambda s, x: s + 1.0)
    pipe = TwoStagePipeline(lambda r: sparse(r), lambda s, r: dense(s, r))
    reqs = [jnp.full((4,), float(i)) for i in range(7)]
    outs, _ = pipe.run(reqs)
    outs_seq, _ = pipe.run_sequential(reqs)
    for a, b in zip(outs, outs_seq):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_steady_state_speedup_bounds():
    assert steady_state_speedup(1.0, 1.0) == pytest.approx(2.0)
    assert steady_state_speedup(1.0, 3.0) == pytest.approx(4.0 / 3.0)


# ---- metrics -------------------------------------------------------------

def test_ne_perfect_predictor_lower_than_base(rng):
    y = jnp.asarray(rng.integers(0, 2, 4096), jnp.float32)
    perfect = (y * 2 - 1) * 8.0
    ne = float(MET.normalized_entropy(perfect, y))
    assert ne < 0.1
    chance = jnp.zeros_like(y)
    assert float(MET.normalized_entropy(chance, y)) == pytest.approx(
        1.0, rel=0.05)


def test_cosine_similarity_self_is_one(key):
    a = jax.random.normal(key, (8, 64))
    assert float(MET.cosine_similarity(a, a)) == pytest.approx(1.0, abs=1e-5)


def test_token_agreement_counts_only_attributable_tokens():
    """Per pair, tokens count up to and including the FIRST mismatch:
    post-divergence tokens condition on different prefixes (greedy
    cascade) and must not dilute or inflate the metric."""
    assert MET.token_agreement([([1, 2, 3], [1, 2, 3])]) == 1.0
    # mismatch at position 1: counts 1 match + 1 miss, ignores the rest
    # (the trailing 9==9 "agreement" is a post-divergence coincidence)
    assert MET.token_agreement([([1, 5, 9], [1, 2, 9])]) \
        == pytest.approx(1 / 2)
    # first token wrong: one counted decision, zero matched
    assert MET.token_agreement([([7, 1, 1], [2, 1, 1])]) == 0.0
    # pools counted decisions across pairs: (3 + 1) matched / (3 + 2)
    assert MET.token_agreement([([1, 2, 3], [1, 2, 3]),
                                ([4, 0, 0], [4, 5, 0])]) \
        == pytest.approx(4 / 5)
    assert MET.token_agreement([]) == 1.0


# ---- numerics golden sets --------------------------------------------------

def test_golden_set_detects_regression(key):
    f = lambda x: x * 2.0
    g = GoldenSet.record(f, (jnp.arange(8.0),))
    ok, _ = g.check(f)
    assert ok
    ok, maxdiff = g.check(lambda x: x * 2.0 + 1e-2)
    assert not ok and maxdiff > 1e-3
