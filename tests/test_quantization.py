"""Quantization (paper §V): property-based guarantees + workflow behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.quantization import (dequantize_rows, dequantize_rows_int4,
                                     dequantize_rows_int8,
                                     quantization_workflow, quantize_act_int8,
                                     quantize_rows, quantize_rows_int4,
                                     quantize_rows_int8, quantize_weight_int8,
                                     w8a8_matmul_ref)


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 32), cols=st.sampled_from([2, 8, 16, 64]),
       seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
def test_int8_rowwise_error_bound(rows, cols, seed, scale):
    """Round-trip error <= half a quantization step per element."""
    rng = np.random.default_rng(seed)
    t = jnp.asarray(rng.normal(size=(rows, cols)) * scale, jnp.float32)
    qt = quantize_rows_int8(t)
    deq = dequantize_rows_int8(qt)
    step = (t.max(axis=1) - t.min(axis=1)) / 255.0
    err = jnp.abs(deq - t).max(axis=1)
    # fp16 storage: scale err <= step*2^-11 (+ subnormal ulp 2^-25 when the
    # step is below fp16's min normal — found by hypothesis), bias err
    # <= |min|*2^-11
    slack = (255 * (step * 2.0 ** -11 + 2.0 ** -25)
             + jnp.abs(t.min(axis=1)) * 2.0 ** -10)
    assert bool(jnp.all(err <= step * 0.5 + slack + 1e-6)), \
        (np.asarray(err), np.asarray(step))


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 32), cols=st.sampled_from([2, 8, 64]),
       seed=st.integers(0, 2**31 - 1))
def test_int4_rowwise_error_bound(rows, cols, seed):
    rng = np.random.default_rng(seed)
    t = jnp.asarray(rng.normal(size=(rows, cols)), jnp.float32)
    qt = quantize_rows_int4(t)
    deq = dequantize_rows_int4(qt)
    step = (t.max(axis=1) - t.min(axis=1)) / 15.0
    err = jnp.abs(deq - t).max(axis=1)
    slack = (15 * (step * 2.0 ** -11 + 2.0 ** -25)
             + jnp.abs(t.min(axis=1)) * 2.0 ** -10)
    assert bool(jnp.all(err <= step * 0.5 + slack + 1e-6))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(4, 64),
       k=st.sampled_from([8, 32]))
def test_w8a8_quant_matmul_close_to_fp32(seed, n, k):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.1, jnp.float32)
    wq, wsc = quantize_weight_int8(w)
    xq, xsc = quantize_act_int8(x)
    got = w8a8_matmul_ref(xq, wq, xsc, wsc)
    want = x @ w
    # int8 x int8 with per-channel scales: ~1% relative error at these sizes
    denom = np.maximum(np.abs(np.asarray(want)), 1.0)
    assert (np.abs(np.asarray(got - want)) / denom).max() < 0.05


def test_int4_packing_roundtrip(key):
    t = jax.random.normal(key, (16, 8))
    qt = quantize_rows(t, 4)
    assert qt["q4"].shape == (16, 4)
    d = dequantize_rows(qt)
    assert d.shape == t.shape


def test_workflow_falls_back_worst_layer_first(key):
    """The paper's loop: highest-error layer -> fp16 until budget met."""
    ks = jax.random.split(key, 3)
    layers = {
        "fc_good": jax.random.normal(ks[0], (32, 32)) * 0.01,
        "fc_outlier": jax.random.normal(ks[1], (32, 32)).at[0, 0].set(100.0),
        "fc_mid": jax.random.normal(ks[2], (32, 32)),
    }

    def eval_metric(schemes):
        # synthetic: outlier layer in int8 costs 1e-3 NE, others 1e-5
        delta = 0.0
        for n, s in schemes.items():
            if s == "int8":
                delta += 1e-3 if n == "fc_outlier" else 1e-5
        return delta

    res = quantization_workflow(layers, eval_metric, budget=5e-4)
    assert res.passed
    schemes = {d.name: d.scheme for d in res.decisions}
    assert schemes["fc_outlier"] == "fp16"      # worst error fell back first
    assert schemes["fc_good"] == "int8"
    assert res.iterations == 1


def test_workflow_gives_up_gracefully(key):
    layers = {"a": jax.random.normal(key, (8, 8))}
    res = quantization_workflow(layers, lambda s: 1.0, budget=1e-4,
                                max_iters=3)
    assert not res.passed
    assert res.iterations <= 3
