"""Quantization (paper §V): property-based guarantees + workflow behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.quantization import (dequantize_rows, dequantize_rows_int4,
                                     dequantize_rows_int8,
                                     quantization_workflow, quantize_act_int8,
                                     quantize_rows, quantize_rows_int4,
                                     quantize_rows_int8, quantize_weight_int8,
                                     w8a8_matmul_ref)


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 32), cols=st.sampled_from([2, 8, 16, 64]),
       seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
def test_int8_rowwise_error_bound(rows, cols, seed, scale):
    """Round-trip error <= half a quantization step per element."""
    rng = np.random.default_rng(seed)
    t = jnp.asarray(rng.normal(size=(rows, cols)) * scale, jnp.float32)
    qt = quantize_rows_int8(t)
    deq = dequantize_rows_int8(qt)
    step = (t.max(axis=1) - t.min(axis=1)) / 255.0
    err = jnp.abs(deq - t).max(axis=1)
    # fp16 storage: scale err <= step*2^-11 (+ subnormal ulp 2^-25 when the
    # step is below fp16's min normal — found by hypothesis), bias err
    # <= |min|*2^-11
    slack = (255 * (step * 2.0 ** -11 + 2.0 ** -25)
             + jnp.abs(t.min(axis=1)) * 2.0 ** -10)
    assert bool(jnp.all(err <= step * 0.5 + slack + 1e-6)), \
        (np.asarray(err), np.asarray(step))


@settings(max_examples=25, deadline=None)
@given(rows=st.integers(1, 32), cols=st.sampled_from([2, 8, 64]),
       seed=st.integers(0, 2**31 - 1))
def test_int4_rowwise_error_bound(rows, cols, seed):
    rng = np.random.default_rng(seed)
    t = jnp.asarray(rng.normal(size=(rows, cols)), jnp.float32)
    qt = quantize_rows_int4(t)
    deq = dequantize_rows_int4(qt)
    step = (t.max(axis=1) - t.min(axis=1)) / 15.0
    err = jnp.abs(deq - t).max(axis=1)
    slack = (15 * (step * 2.0 ** -11 + 2.0 ** -25)
             + jnp.abs(t.min(axis=1)) * 2.0 ** -10)
    assert bool(jnp.all(err <= step * 0.5 + slack + 1e-6))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(4, 64),
       k=st.sampled_from([8, 32]))
def test_w8a8_quant_matmul_close_to_fp32(seed, n, k):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.1, jnp.float32)
    wq, wsc = quantize_weight_int8(w)
    xq, xsc = quantize_act_int8(x)
    got = w8a8_matmul_ref(xq, wq, xsc, wsc)
    want = x @ w
    # int8 x int8 with per-channel scales: ~1% relative error at these sizes
    denom = np.maximum(np.abs(np.asarray(want)), 1.0)
    assert (np.abs(np.asarray(got - want)) / denom).max() < 0.05


def test_int4_packing_roundtrip(key):
    t = jax.random.normal(key, (16, 8))
    qt = quantize_rows(t, 4)
    assert qt["q4"].shape == (16, 4)
    d = dequantize_rows(qt)
    assert d.shape == t.shape


def test_workflow_falls_back_worst_layer_first(key):
    """The paper's loop: highest-error layer -> fp16 until budget met."""
    ks = jax.random.split(key, 3)
    layers = {
        "fc_good": jax.random.normal(ks[0], (32, 32)) * 0.01,
        "fc_outlier": jax.random.normal(ks[1], (32, 32)).at[0, 0].set(100.0),
        "fc_mid": jax.random.normal(ks[2], (32, 32)),
    }

    def eval_metric(schemes):
        # synthetic: outlier layer in int8 costs 1e-3 NE, others 1e-5
        delta = 0.0
        for n, s in schemes.items():
            if s == "int8":
                delta += 1e-3 if n == "fc_outlier" else 1e-5
        return delta

    res = quantization_workflow(layers, eval_metric, budget=5e-4)
    assert res.passed
    schemes = {d.name: d.scheme for d in res.decisions}
    assert schemes["fc_outlier"] == "fp16"      # worst error fell back first
    assert schemes["fc_good"] == "int8"
    assert res.iterations == 1


def test_workflow_gives_up_gracefully(key):
    layers = {"a": jax.random.normal(key, (8, 8))}
    res = quantization_workflow(layers, lambda s: 1.0, budget=1e-4,
                                max_iters=3)
    assert not res.passed
    assert res.iterations <= 3


# ---- QuantizedParams build step (PR 6, serving w8a8) -----------------------

@pytest.fixture(scope="module")
def lm_smoke():
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import model as M
    cfg = reduce_for_smoke(get_config("deepseek-7b"))
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def test_build_quantized_params_site_and_leaf_contract(lm_smoke):
    """Every dense projection becomes a site; int8-decided sites carry
    exactly the {q8 int8 (reduction, out), scale f32 (out,)} leaf the
    w8a8 kernels consume (arrays only — the leaves must slice through
    jax.lax.scan like the fp32 originals)."""
    from repro.models.quantize import (QUANT_SITES, _collect_sites,
                                       build_quantized_params)
    cfg, params = lm_smoke
    sites = _collect_sites(params)
    per_block = sum(len(v) for v in QUANT_SITES.values())
    assert len(sites) == per_block * (len(params.get("scan", ()))
                                      + len(params.get("tail", ())))
    qp = build_quantized_params(cfg, params, budget=0.05)
    assert qp.quantized_sites + qp.fallback_sites == len(sites)
    assert qp.quantized_sites > 0
    assert float(qp.result.metric_delta) <= 0.05
    for name, scheme in qp.schemes.items():
        group, gi, mod, wname = \
            _collect_sites(params)[name]
        leaf = qp.params[group][int(gi)][mod][wname]
        if scheme == "int8":
            assert set(leaf) == {"q8", "scale"}
            assert leaf["q8"].dtype == jnp.int8
            assert leaf["scale"].dtype == jnp.float32
            # scan sites keep the stacked repeats dim in front
            extra = 1 if group == "scan" else 0
            assert leaf["q8"].ndim == 2 + extra
            assert leaf["scale"].ndim == 1 + extra
            assert leaf["q8"].shape[-1] == leaf["scale"].shape[-1]
        else:
            assert not isinstance(leaf, dict)      # fp32 original kept


def test_build_skip_list_substring_filters(lm_smoke):
    """skip=('wo',) force-keeps every output projection fp32 — substring
    match, exactly the core workflow's skip-list semantics."""
    from repro.models.quantize import build_quantized_params
    cfg, params = lm_smoke
    qp = build_quantized_params(cfg, params, skip=("wo",))
    assert qp.schemes
    assert not any(".wo" in name for name in qp.schemes)


def test_build_falls_back_under_impossible_budget(lm_smoke):
    """A budget no mix can meet drives the loop to fall sites back (paper
    §V: raise precision for high-error operators) and report not-passed
    instead of looping forever."""
    from repro.models.quantize import build_quantized_params
    cfg, params = lm_smoke
    qp = build_quantized_params(cfg, params, budget=-1.0, max_iters=2)
    assert not qp.result.passed
    assert qp.fallback_sites > 0
    assert qp.result.iterations <= 2


def test_build_on_siteless_arch_is_empty_not_an_error():
    """SSM mixers touch their weights directly, so a pure-Mamba stack has
    zero dense-projection sites — the build degrades to a no-op (all-fp32
    run params), it does not crash."""
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import model as M
    from repro.models.quantize import build_quantized_params
    cfg = reduce_for_smoke(get_config("mamba2-130m"))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    qp = build_quantized_params(cfg, params)
    assert qp.quantized_sites == 0 and qp.fallback_sites == 0
    assert not qp.schemes


# ---- BENCH_quant.json schema ----------------------------------------------

def _fake_quant_payload():
    return {
        "dlrm_embed": {
            "budget": 5e-4,
            "int8": {"ne_delta": 1e-5, "within_budget": True},
            "int4": {"ne_delta": 2e-4, "within_budget": True},
        },
        "workflow": {"passed": True, "ne_delta": 1e-5, "budget": 5e-4,
                     "iterations": 1, "fp16_fallbacks": 0,
                     "fallback_layers": []},
        "mixed48": {"ne_delta": 1e-4, "within_budget": True, "budget": 5e-4,
                    "int4_tables": 3, "num_tables": 4, "upgrades": 1,
                    "bytes_vs_int8": 0.6},
        "backbone": {"arch": "gemma-2b", "cosine": 0.999,
                     "requirement": 0.98, "within": True},
        "w8a8_build": {"arch": "deepseek-7b", "budget": 0.05,
                       "quantized_sites": 7, "fallback_sites": 0,
                       "fallback_names": [], "calib_disagreement": 0.0,
                       "within_budget": True},
    }


def test_bench_quant_schema_accepts_complete_payload():
    from benchmarks.bench_quant import validate_payload
    validate_payload(_fake_quant_payload())


def test_bench_quant_schema_rejects_missing_keys():
    from benchmarks.bench_quant import validate_payload
    p = _fake_quant_payload()
    del p["w8a8_build"]["calib_disagreement"]
    del p["dlrm_embed"]["int8"]["ne_delta"]
    del p["backbone"]
    with pytest.raises(ValueError) as ei:
        validate_payload(p)
    msg = str(ei.value)
    assert "w8a8_build.calib_disagreement" in msg
    assert "dlrm_embed.int8.ne_delta" in msg
    assert "backbone" in msg


def test_bench_quant_emit_round_trips(tmp_path):
    import json
    from benchmarks.bench_quant import emit
    path = tmp_path / "BENCH_quant.json"
    emit(_fake_quant_payload(), path=str(path))
    assert json.loads(path.read_text()) == _fake_quant_payload()


def test_bench_quant_emit_unwritable_exits_nonzero(tmp_path, capsys):
    from benchmarks.bench_quant import emit
    blocker = tmp_path / "not-a-dir"
    blocker.write_text("file, not a directory")
    with pytest.raises(SystemExit) as ei:
        emit(_fake_quant_payload(), path=str(blocker / "BENCH_quant.json"))
    assert ei.value.code == 1
    assert "cannot write" in capsys.readouterr().err
