"""Fleet-simulation property harness (PR 4 work stealing).

The simulator core lives in ``repro.serving.fleet_sim`` (it is runtime
infrastructure: the bench's ``work_stealing`` section runs it too); this
module is the test-facing surface — re-exports plus the seeded
random-schedule driver the property suite uses to push the fleet through
thousands of submit / steal / fail / complete interleavings with zero
wall-clock flakiness. Everything is keyed off one ``numpy`` Generator, so
a fixed seed reproduces the exact schedule, completion order, and steal
attribution.
"""
import numpy as np

from repro.serving.fleet_sim import FleetSim, SimReplica  # noqa: F401


def random_schedule(sim: FleetSim, n_ops: int, *, p_submit: float = 0.55,
                    skew: float = 0.0, hot: int = 0,
                    fail_at: int = -1, slo_ms=None,
                    max_priority: int = 0, p_page: float = 0.0,
                    p_migrate: float = 0.0) -> int:
    """Drive ``sim`` through ``n_ops`` seeded events: each op is a submit
    (probability ``p_submit``; pinned to replica ``hot`` with probability
    ``skew`` — the hot-keyed stream) or a tick; op ``fail_at`` (if in
    range and a live sibling remains) kills the currently most-loaded
    live replica mid-run. With probability ``p_page`` / ``p_migrate``
    each op ALSO fires a movable-state event (PR 8) before the
    submit-or-tick: a page_out or page_in on a random replica, or a
    migrate between a random (src, dst) pair — conservation and ticket
    identity must survive any interleaving of these with steals, fails,
    and drains. Returns the index of the failed replica (-1 if none).
    The caller drains and asserts afterwards."""
    failed = -1
    n = len(sim.replicas)
    for op in range(n_ops):
        if op == fail_at and len(sim.router.alive) > 1:
            alive = sim.router.alive
            failed = max(alive, key=lambda i: (sim.router.load(i), i))
            sim.fail(failed)
        if p_page > 0 and sim.rng.random() < p_page:
            idx = int(sim.rng.integers(0, n))
            if sim.rng.random() < 0.5:
                sim.page_out(idx)
            else:
                sim.page_in(idx)
        if p_migrate > 0 and sim.rng.random() < p_migrate:
            sim.migrate(int(sim.rng.integers(0, n)),
                        int(sim.rng.integers(0, n)))
        if sim.rng.random() < p_submit:
            pin = None
            if skew > 0 and sim.rng.random() < skew \
                    and not sim.router.dead[hot]:
                pin = hot
            sim.submit(size=int(sim.rng.integers(1, 8)),
                       priority=int(sim.rng.integers(0, max_priority + 1)),
                       slo_ms=slo_ms, pin=pin)
        else:
            sim.tick()
    return failed


def run_to_completion(sim: FleetSim) -> list:
    """Drain the fleet and return the completion order as payload ids
    (the determinism fingerprint, together with steal attribution)."""
    sim.drain()
    return [t.payload for t in sim.completed]


def make_controller(sim: FleetSim, *, min_replicas: int = 1,
                    max_replicas: int = 8, cooldown_s: float = 0.2,
                    down_hold_s: float = 0.5, timeout_s: float = 0.05,
                    service_s: float = 0.01, **cfg_kw):
    """Wire a FleetController to ``sim`` (PR 7 elastic tests): heartbeat
    monitor on the sim's virtual clock, scale-up factory building
    replicas that join the sim's conservation tracking."""
    from repro.runtime.fault_tolerance import HeartbeatMonitor
    from repro.serving.controller import ControllerConfig, FleetController
    mon = HeartbeatMonitor(num_hosts=len(sim.replicas),
                           timeout_s=timeout_s, clock=lambda: sim.now)
    return FleetController(
        sim.router, sim.replica_factory(service_s=service_s), mon,
        ControllerConfig(min_replicas=min_replicas,
                         max_replicas=max_replicas, cooldown_s=cooldown_s,
                         down_hold_s=down_hold_s, **cfg_kw))
