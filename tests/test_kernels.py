"""Per-kernel shape/dtype sweeps against the ref.py oracles (paper §V-C)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.decode_attn.ops  # registers ops
import repro.kernels.flash_attn.ops
import repro.kernels.sls.ops
import repro.kernels.w8a8.ops
from repro.core.numerics import registered_ops, validate_all, validate_op
from repro.kernels.decode_attn.decode import flash_decode
from repro.kernels.decode_attn.ref import decode_attn_ref
from repro.kernels.sls.ref import sls_int8_ref, sls_ref
from repro.kernels.sls.sls import sls_int8_pallas, sls_pallas
from repro.kernels.w8a8.matmul import w8a8_matmul
from repro.kernels.w8a8.ref import w8a8_ref


def test_registry_has_all_kernels():
    ops = registered_ops()
    for name in ("sls_fp32", "sls_int8", "sls_int4", "w8a8_matmul",
                 "flash_decode", "flash_decode_softcap",
                 "flash_attn_mha_64", "flash_attn_gqa_128",
                 "flash_attn_local_128", "flash_attn_bf16"):
        assert name in ops


@pytest.mark.parametrize("op", ["sls_fp32", "sls_int8", "sls_int4",
                                "w8a8_matmul", "flash_decode",
                                "flash_decode_softcap",
                                "flash_attn_mha_64", "flash_attn_gqa_128",
                                "flash_attn_mqa_256", "flash_attn_local_128",
                                "flash_attn_softcap", "flash_attn_padded_lens",
                                "flash_attn_noncausal", "flash_attn_odd_seq_96",
                                "flash_attn_bf16", "flash_decode_int8"])
def test_kernel_validates(op):
    for rep in validate_op(op):
        assert rep.passed, (rep.op, rep.case, rep.max_abs, rep.max_rel)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,K,hd,S", [(2, 4, 2, 64, 128), (1, 8, 8, 32, 64)])
def test_flash_decode_dtypes(dtype, B, H, K, hd, S, key):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    pos = jnp.int32(S // 2)
    got = flash_decode(q, k, v, pos, bs=32)
    want = decode_attn_ref(q, k, v, pos)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol)


def test_flash_decode_block_size_invariance(key):
    """Output must not depend on the KV block size (online softmax)."""
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (2, 4, 2, 32)).reshape(2, 8, 32)
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    outs = [np.asarray(flash_decode(q, k, v, jnp.int32(77), bs=bs))
            for bs in (16, 32, 64, 128)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-6)


def test_w8a8_bitwise_vs_ref(key):
    """int32 accumulation is exact: kernel must match the oracle bitwise."""
    k1, k2 = jax.random.split(key)
    xq = jax.random.randint(k1, (256, 128), -127, 128).astype(jnp.int8)
    wq = jax.random.randint(k2, (128, 256), -127, 128).astype(jnp.int8)
    ws = jnp.linspace(0.001, 0.02, 256).astype(jnp.float32)
    got = np.asarray(w8a8_matmul(xq, wq, jnp.float32(0.013), ws))
    want = np.asarray(w8a8_ref(xq, wq, jnp.float32(0.013), ws))
    assert (got == want).all()


def test_sls_empty_bags(key):
    """lengths=0 bags must pool to exactly zero."""
    table = jax.random.normal(key, (64, 16))
    idx = jnp.zeros((4, 8), jnp.int32)
    lens = jnp.zeros((4,), jnp.int32)
    out = np.asarray(sls_pallas(table, idx, lens))
    assert (out == 0).all()


def test_sls_matches_dlrm_quant_path(key):
    """Kernel dequant semantics == core.quantization row-wise scheme."""
    from repro.core.quantization import quantize_rows_int8
    table = jax.random.normal(key, (128, 32))
    qt = quantize_rows_int8(table)
    idx = jax.random.randint(key, (8, 4), 0, 128)
    lens = jnp.full((8,), 3, jnp.int32)
    got = np.asarray(sls_int8_pallas(qt["q8"], qt["scale"], qt["bias"],
                                     idx, lens))
    want = np.asarray(sls_int8_ref(qt["q8"], qt["scale"], qt["bias"],
                                   idx, lens))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_validate_all_passes():
    reports = validate_all()
    assert reports and all(r.passed for r in reports), \
        [(r.op, r.case) for r in reports if not r.passed]


# ---- flash prefill/train attention: model-path equivalence ------------------

def test_flash_pallas_matches_model_attention(key):
    """The flash_pallas model path == the chunked_jnp path (same numerics
    modulo online-softmax reassociation)."""
    import dataclasses
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import model as M

    cfg = reduce_for_smoke(get_config("gemma2-27b"))   # local+global, softcap
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab_size)
    p = M.init_params(cfg, key)
    h1, _, _ = M.forward(p, cfg, {"tokens": toks}, mode="full")
    cfg2 = dataclasses.replace(cfg, attention_impl="flash_pallas")
    h2, _, _ = M.forward(p, cfg2, {"tokens": toks}, mode="full")
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=2e-3, atol=2e-3)


# ---- int8 KV cache (paper T3 applied to the decode path) --------------------

def test_int8_kv_cache_decode_close(key):
    import dataclasses
    from repro.configs import QuantConfig, get_config, reduce_for_smoke
    from repro.models import model as M
    from repro.serving.engine import InferenceEngine, Request

    cfg = reduce_for_smoke(get_config("deepseek-7b"))
    cfg_q = dataclasses.replace(
        cfg, quant=dataclasses.replace(cfg.quant, kv_cache_dtype="int8"))
    p = M.init_params(cfg, key)
    toks = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)

    def run(c):
        x, caches = M.prefill(p, c, {"tokens": toks}, max_len=32)
        h, caches = M.decode_step(p, c, toks[:, -1:], caches,
                                  jnp.full((2,), 12, jnp.int32))
        return h, x

    (h_ref, x_ref) = run(cfg)
    (h_q, x_q) = run(cfg_q)
    # decode hidden states stay close under int8 cache quantization
    cos = float(jnp.mean(jnp.sum(h_ref * h_q, -1) / jnp.maximum(
        jnp.linalg.norm(h_ref, axis=-1) * jnp.linalg.norm(h_q, axis=-1),
        1e-9)))
    assert cos > 0.99, cos
    # prefill last-hidden close (prefill attends full-precision k/v before
    # caching); the int8 effect shows only at decode
    np.testing.assert_allclose(np.asarray(x_ref), np.asarray(x_q),
                               rtol=1e-4, atol=1e-4)

    # engine runs end-to-end with the quantized cache
    eng = InferenceEngine(cfg_q, p, batch_slots=2, max_len=64,
                          prefill_buckets=(8, 16))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
                    max_new_tokens=4) for i in range(3)]
    eng.run(reqs)
    assert eng.stats.served == 3
    assert all(len(r.output) >= 4 for r in reqs)
