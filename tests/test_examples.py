"""Keep the examples runnable: each public script must exit 0 (smoke-size).
quickstart covers model+engine+numerics; quantization_workflow covers the
SecV-B loop; the serving/training drivers are exercised with tiny knobs."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script, *args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, script), *args],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=timeout)


@pytest.mark.parametrize("script,args", [
    ("examples/quickstart.py", ()),
    ("examples/quantization_workflow.py", ()),
    ("examples/serve_recsys.py", ("--batches", "4")),
    ("examples/serve_router.py", ()),
    ("examples/serve_elastic.py", ()),
])
def test_example_runs(script, args):
    r = _run(script, *args)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    assert r.stdout.strip(), script
