"""Per-assigned-architecture smoke tests (deliverable f): reduced same-family
config, one forward + one train step on CPU, asserting shapes and no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduce_for_smoke
from repro.models import model as M
from repro.training.optimizer import OptConfig, init_opt_state
from repro.training.train_step import make_train_step

B, S = 2, 16


def _batch(cfg, key):
    batch = {}
    if cfg.encdec is not None:
        batch["enc_embeds"] = jax.random.normal(key, (B, 24, cfg.d_model),
                                                jnp.float32) * 0.1
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    elif cfg.input_kind == "embeddings":
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.float32) * 0.1
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + ("xlmr-paper",))
def test_forward_shapes_no_nan(arch, key):
    cfg = reduce_for_smoke(get_config(arch))
    params = M.init_params(cfg, key)
    batch = _batch(cfg, key)
    x, _, aux = M.forward(params, cfg, batch, mode="full")
    assert x.shape == (B, S, cfg.d_model)
    assert not bool(jnp.isnan(x).any())
    loss, parts = M.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch, key):
    cfg = reduce_for_smoke(get_config(arch))
    params = M.init_params(cfg, key)
    opt_cfg = OptConfig(name="adam", lr=1e-3)
    opt_state = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg, accum_steps=1, remat=False))
    batch = _batch(cfg, key)
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    changed = jax.tree.map(
        lambda a, b: bool(jnp.any(a != b)), params, params2)
    assert any(jax.tree.leaves(changed))


@pytest.mark.parametrize("arch", ["gemma-2b", "gemma2-27b", "mamba2-130m",
                                  "recurrentgemma-9b", "whisper-medium",
                                  "kimi-k2-1t-a32b"])
def test_prefill_decode_matches_full(arch, key):
    cfg = reduce_for_smoke(get_config(arch))
    if cfg.moe is not None:    # capacity drops are batch-composition-dependent
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = M.init_params(cfg, key)
    S_, pre = 12, 8
    if cfg.encdec is not None:
        enc = jax.random.normal(key, (B, 16, cfg.d_model)) * 0.1
        toks = jax.random.randint(key, (B, S_), 0, cfg.vocab_size)
        full_b = {"tokens": toks, "enc_embeds": enc}
        pre_b = {"tokens": toks[:, :pre], "enc_embeds": enc}
    else:
        toks = jax.random.randint(key, (B, S_), 0, cfg.vocab_size)
        full_b = {"tokens": toks}
        pre_b = {"tokens": toks[:, :pre]}
    xf, _, _ = M.forward(params, cfg, full_b, mode="full")
    h, caches = M.prefill(params, cfg, pre_b, max_len=32)
    np.testing.assert_allclose(np.asarray(h), np.asarray(xf[:, pre - 1]),
                               rtol=2e-4, atol=2e-4)
    for t in range(pre, S_):
        h, caches = M.decode_step(params, cfg, toks[:, t:t + 1], caches,
                                  jnp.int32(t))
        np.testing.assert_allclose(np.asarray(h), np.asarray(xf[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_param_counts_match_spec():
    expect = {"gemma-2b": 2.5e9, "deepseek-7b": 6.9e9,
              "command-r-plus-104b": 104e9, "gemma2-27b": 27e9,
              "kimi-k2-1t-a32b": 1.04e12, "dbrx-132b": 132e9,
              "mamba2-130m": 0.13e9, "whisper-medium": 0.66e9,
              "qwen2-vl-7b": 7.6e9, "recurrentgemma-9b": 8.6e9}
    for arch, want in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.12, (arch, got, want)


def test_moe_active_params():
    kimi = get_config("kimi-k2-1t-a32b")
    assert 25e9 < kimi.active_param_count() < 40e9
    dbrx = get_config("dbrx-132b")
    assert 30e9 < dbrx.active_param_count() < 45e9
