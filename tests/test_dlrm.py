"""DLRM (paper centerpiece): SLS correctness, quantized tables, NE metric
sensitivity, serving engine pipeline equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import dlrm_paper
from repro.core.metrics import ne_delta
from repro.data.synthetic import dlrm_batches
from repro.models import dlrm as D
from repro.serving.dlrm_engine import DLRMEngine


@pytest.fixture(scope="module")
def setup():
    cfg = dlrm_paper.reduce_for_smoke(dlrm_paper.PAPER_BASE)
    asn = D.make_assignment(cfg, 4)
    key = jax.random.PRNGKey(0)
    params = D.init_dlrm(cfg, asn, key)
    batch = next(dlrm_batches(cfg, 32, seed=3))
    b = {k: jnp.asarray(v) for k, v in batch.items()}
    return cfg, asn, params, b


def test_sls_masked_pooling(setup):
    cfg, asn, params, b = setup
    pooled = D.sls_forward(params, cfg, asn, b["indices"], b["lengths"])
    assert pooled.shape == (32, cfg.num_tables, cfg.embed_dim)
    # zero-length bags pool to zero
    lens0 = jnp.zeros_like(b["lengths"])
    p0 = D.sls_forward(params, cfg, asn, b["indices"], lens0)
    assert bool((p0 == 0).all())


def test_quantized_sls_close(setup, key):
    cfg, asn, params, b = setup
    pq = D.init_dlrm(cfg, asn, key, quantize=True)
    ref = D.init_dlrm(cfg, asn, key, quantize=False)
    a = D.sls_forward(ref, cfg, asn, b["indices"], b["lengths"])
    q = D.sls_forward(pq, cfg, asn, b["indices"], b["lengths"])
    rel = float(jnp.abs(a - q).max() / (jnp.abs(a).max() + 1e-9))
    assert rel < 0.02


def test_dlrm_loss_and_logits(setup):
    cfg, asn, params, b = setup
    loss, logits = D.dlrm_loss(params, cfg, asn, b)
    assert np.isfinite(float(loss))
    assert logits.shape == (32,)


def test_ne_delta_small_for_int8(setup, key):
    cfg, asn, params, b = setup
    pq = {**params}
    pq.pop("slab", None)
    full = D.init_dlrm(cfg, asn, key, quantize=False)
    quant = {**full}
    from repro.core.quantization import quantize_rows
    quant["slab_q"] = quantize_rows(full["slab"], 8)
    del quant["slab"]
    lr = D.dlrm_forward(full, cfg, asn, b["dense"], b["indices"], b["lengths"])
    lq = D.dlrm_forward(quant, cfg, asn, b["dense"], b["indices"], b["lengths"])
    d = abs(ne_delta(lq, lr, b["labels"]))
    assert d < 0.02          # smoke-scale bound; paper budget 5e-4 at scale


def test_engine_pipelined_matches_sequential(setup):
    cfg, asn, params, _ = setup
    eng = DLRMEngine(cfg, asn, params)
    batches = [next(dlrm_batches(cfg, 8, seed=s)) for s in range(5)]
    outs_p, _ = eng.serve(batches, pipelined=True)
    outs_s, _ = eng.serve(batches, pipelined=False)
    for a, b_ in zip(outs_p, outs_s):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=1e-6, atol=1e-6)
    assert eng.transfer_stats.bytes_saved_frac > 0.0
