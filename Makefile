PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test properties smoke smoke-router smoke-chunked smoke-steal \
	smoke-quant smoke-elastic smoke-prefix smoke-fleet-prefix \
	smoke-autotune perf-gate bench ci

test:
	python -m pytest -x -q

# scheduler-policy invariants at a pinned seed (works with real
# hypothesis or the conftest fallback shim)
properties:
	python -m pytest -q tests/test_scheduler_properties.py \
	    --hypothesis-seed=0

smoke:
	python -m repro.launch.serve --arch deepseek-7b --smoke \
	    --requests 6 --new-tokens 4 --slots 2
	python -m repro.launch.serve --arch dlrm --smoke --requests 6

# 2-replica ReplicaRouter smoke, both archs (priority policy on the LM)
smoke-router:
	python -m repro.launch.serve --arch deepseek-7b --smoke \
	    --requests 8 --new-tokens 4 --slots 2 --replicas 2 \
	    --policy priority --slo-ms 60000
	python -m repro.launch.serve --arch dlrm --smoke --requests 6 \
	    --replicas 2

# chunked-prefill smoke: serve a mixed trace with chunking on, then
# replay it monolithically and assert token-identical outputs — on the
# all-global arch AND on a stateful hybrid (RG-LRU + local ring), the
# stacks the SequenceStateManager (PR 5) opened to chunking
smoke-chunked:
	python -m repro.launch.serve --arch deepseek-7b --smoke \
	    --requests 8 --new-tokens 4 --slots 2 --max-len 64 \
	    --prefill-chunk 16 --verify-chunked
	python -m repro.launch.serve --arch recurrentgemma-9b --smoke \
	    --requests 8 --new-tokens 4 --slots 2 --max-len 64 \
	    --prefill-chunk 16 --verify-chunked

# work-stealing smoke: 2-replica fleet, every request hot-spotted onto
# replica 0, replica 0 killed mid-run — asserts nonzero telemetry.steals
# and a fault drain that loses zero tickets
smoke-steal:
	python -m repro.launch.serve --arch deepseek-7b --smoke \
	    --requests 8 --new-tokens 4 --slots 2 --replicas 2 \
	    --steal --verify-steal

# quantized-serving smoke (PR 6): single w8a8 engine replays its trace
# on fp32 and asserts the greedy-token-agreement guardrail; then a mixed
# fp32+w8a8 fleet (feedback routing + stealing) asserts every class-0
# request pinned to the fp32 replica with zero lost and zero downgrades
smoke-quant:
	python -m repro.launch.serve --arch deepseek-7b --smoke \
	    --requests 8 --new-tokens 4 --slots 3 --max-len 64 \
	    --prefill-chunk 16 --precision w8a8 --verify-quant
	python -m repro.launch.serve --arch deepseek-7b --smoke \
	    --requests 16 --new-tokens 4 --slots 3 --max-len 64 \
	    --replicas 2 --replica-precisions fp32,w8a8 --route feedback \
	    --steal --policy priority --verify-quant

# elastic-fleet smoke (PR 7): flash crowd + mid-crowd card freeze on the
# deterministic fleet sim — asserts scale-up, trough scale-down, exactly
# one missed-heartbeat fault drain, zero lost, and both wins vs a fixed
# fleet (less peak shedding, fewer replica-seconds)
smoke-elastic:
	python -m repro.launch.serve --elastic-smoke

# prefix-cache smoke (PR 8): populate the content-hash prefix cache
# with a hot-system-prompt trace, replay it through the warm cache, and
# assert nonzero hits with every output token-identical to a cold
# engine serving the same trace
smoke-prefix:
	python -m repro.launch.serve --arch deepseek-7b --smoke \
	    --requests 8 --new-tokens 4 --prefill-chunk 16 \
	    --prefix-cache 16 --verify-prefix

# fleet-prefix smoke (PR 10): 2-replica fleet with the fleet-shared
# prefix tier under a hot-system-prompt trace — populate one replica,
# then route the rest through locality-aware steering and assert
# nonzero remote hits, zero lost, outputs token-identical to cold
# prefill
smoke-fleet-prefix:
	python -m repro.launch.serve --arch deepseek-7b --smoke \
	    --requests 10 --new-tokens 4 --prefill-chunk 16 \
	    --prefix-cache 16 --replicas 2 --verify-fleet-prefix

# self-tuning-knob smoke (PR 9): serve with --prefill-chunk auto — the
# analytic perf model (seeded from the bench's published calibration
# when results/BENCH_serving.json is present) picks the chunk at the
# per-bucket efficiency knee — and assert the chosen chunk sits on the
# ladder at or below the bench-measured knee, with outputs
# token-identical to a hand-set reference chunk
smoke-autotune:
	python -m repro.launch.serve --arch deepseek-7b --smoke \
	    --requests 8 --new-tokens 4 --slots 2 --max-len 64 \
	    --prefill-chunk auto --verify-autotune

# perf-regression gate: named deterministic scenarios vs the bounds in
# results/PERF_REFERENCES.json — exits 1 loudly on any violation
perf-gate:
	python benchmarks/perf_gate.py

bench:
	python -m benchmarks.run --only serving

ci: test properties smoke smoke-router smoke-chunked smoke-steal \
	smoke-quant smoke-elastic smoke-prefix smoke-fleet-prefix \
	smoke-autotune perf-gate bench
