PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test smoke bench ci

test:
	python -m pytest -x -q

smoke:
	python -m repro.launch.serve --arch deepseek-7b --smoke \
	    --requests 6 --new-tokens 4 --slots 2
	python -m repro.launch.serve --arch dlrm --smoke --requests 6

bench:
	python -m benchmarks.run --only serving

ci: test smoke bench
